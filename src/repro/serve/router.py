"""Multi-replica routing over one mesh (the ROADMAP's PR-3 follow-on).

The threaded :class:`~repro.serve.anns_service.BatchingANNSService` is the
per-replica building block: one pump thread + one ticker per replica keeps
a single device group busy.  Serving heavy traffic from one box is then a
ROUTING problem — saturate the whole device tier with many concurrent
query streams.  :class:`ReplicaRouter` fronts N such replicas:

* **one mesh, disjoint device groups** — ``launch.mesh.split_mesh`` carves
  the shared mesh into N sub-meshes; each replica's
  :class:`~repro.core.executor.QueryExecutor` row-shards the PQ corpus
  over ITS group only (``core.distributed`` commits every scan operand to
  the sub-mesh), so concurrent per-replica ADC scans never contend for a
  chip.  Without a mesh (tests, 1-device hosts) every replica runs
  unsharded on the default device and the router is a pure concurrency
  layer.
* **same futures-first surface** — ``submit() -> QueryFuture`` with
  ``k``/``top_n``/``deadline_s``, backpressure (a submission rejected by
  every replica raises :class:`BackpressureError`), graceful fan-out
  ``stop()`` drain, aggregated ``latency_percentiles()`` and a
  ``QueryStats`` rollup.
* **pluggable policies** —

  ============= =========================================================
  policy        choice per request
  ============= =========================================================
  round_robin   cycle through replicas (stateless, cache-friendly)
  jsq           join-shortest-queue: each replica's LIVE request count
                (``BatchingANNSService.live_load()`` — uncancelled queued
                + in-flight) picks the least-loaded replica
  deadline      round-robin baseline, but a request carrying a deadline
                spills to the least-loaded replica when that is strictly
                less loaded than the round-robin pick
  ============= =========================================================

  Every policy also SPILLS on backpressure: when the chosen replica's
  queue is full the router tries the remaining replicas (least-loaded
  first) before rejecting.
* **update propagation** — replicas share ONE index object (posting
  lists, tombstones, SSD tier, the ``codes`` binding), so
  ``router.insert()/delete()`` are visible to every replica: an insert
  rebinds ``index.codes`` and each replica's executor re-places its HBM
  shard on its next dispatch; deletes tombstone in DRAM and are filtered
  at candidate collection on every replica (``test_updates`` semantics
  hold under routing).

Routing never changes results: each replica runs the same unified
executor pipeline over the same index, so ids are bit-identical to a
single-replica ``run()`` under every policy (tests/test_router.py).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine import FusionANNSIndex
from repro.core.executor import QUERY_STATS_FIELDS
from repro.core.futures import BackpressureError, QueryFuture
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import SearchRequest, SearchResponse

__all__ = ["ReplicaRouter", "POLICIES"]

POLICIES = ("round_robin", "jsq", "deadline")


class ReplicaRouter:
    """Fronts N serving replicas with one futures-first ``submit()``."""

    def __init__(self, index: FusionANNSIndex, *, n_replicas: int = 2,
                 policy: str = "jsq", mesh=None, threaded: bool = True,
                 **svc_kw):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.index = index
        self.policy = policy
        if mesh is not None:
            from repro.launch.mesh import split_mesh
            self.meshes = split_mesh(mesh, n_replicas)
        else:
            self.meshes = [None] * n_replicas
        # each replica: own executor (own sub-mesh, own dispatch lock, own
        # HBM placement) wrapped by its own pump/ticker service
        self.replicas: List[BatchingANNSService] = [
            BatchingANNSService(index, executor=index.make_executor(m),
                                threaded=threaded, **svc_kw)
            for m in self.meshes]
        # mirrors the replicas' harness (clients read this to pick their
        # backpressure strategy: sleep-retry vs pump-on-behalf)
        self.threaded = threaded
        self._lock = threading.Lock()
        self._rr = 0                       # round-robin cursor
        self.stats: Dict[str, object] = {
            "submitted": 0, "rejected": 0, "spills": 0,
            "deadline_spills": 0, "routed": [0] * n_replicas}

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ReplicaRouter":
        for r in self.replicas:
            r.start()
        self.threaded = True
        return self

    def stop(self) -> "ReplicaRouter":
        """Graceful fan-out drain: every replica's pump thread serves its
        remaining queue (zero pending futures survive), in parallel."""
        ts = [threading.Thread(target=r.stop) for r in self.replicas]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        self.threaded = False
        return self

    def __enter__(self) -> "ReplicaRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --------------------------------------------------------------- routing
    def _route_order(self, deadline_s: Optional[float]
                     ) -> tuple[Sequence[int], Optional[int]]:
        """Replica indices to try (primary choice first) plus the
        deadline-spill target, if this request jumped the round-robin
        line.  Fallbacks (the backpressure spill path) go least-loaded
        first."""
        n = len(self.replicas)
        if n == 1:
            return (0,), None
        loads = [r.live_load() for r in self.replicas]
        by_load = sorted(range(n), key=lambda i: (loads[i], i))
        if self.policy == "jsq":
            return by_load, None
        with self._lock:
            start = self._rr
            self._rr = (self._rr + 1) % n
        if self.policy == "deadline" and deadline_s is not None:
            least = by_load[0]
            if loads[least] < loads[start]:
                # deadline-aware spill: tight-deadline traffic jumps to
                # the least-loaded replica instead of waiting in line
                return ([least] + [i for i in by_load if i != least],
                        least)
        # primary = the round-robin pick; backpressure fallbacks go
        # least-loaded first (the documented spill order)
        return [start] + [i for i in by_load if i != start], None

    def submit(self, request: SearchRequest) -> QueryFuture:
        """Route one request; returns the serving replica's future (same
        surface as ``BatchingANNSService.submit`` — a typed
        :class:`~repro.serve.client.SearchRequest` in, a future resolving
        to a :class:`~repro.serve.client.SearchResponse` out).  Tries
        the policy's choice first, spills across the remaining replicas on
        backpressure, and raises :class:`BackpressureError` only when
        EVERY replica's queue is full."""
        if not isinstance(request, SearchRequest):
            raise TypeError(
                "submit() takes a SearchRequest; wrap raw query vectors "
                "with as_request(...) or use ANNSClient "
                f"(got {type(request).__name__})")
        req = request
        order, dl_target = self._route_order(req.deadline_s)
        last: Optional[BackpressureError] = None
        for pos, i in enumerate(order):
            try:
                fut = self.replicas[i].submit(req)
            except BackpressureError as exc:
                last = exc
                continue
            with self._lock:
                self.stats["submitted"] += 1
                self.stats["routed"][i] += 1
                if pos:
                    self.stats["spills"] += 1
                # counted only when the request actually LANDED on the
                # spill target (not when the spill was merely chosen)
                if dl_target is not None and i == dl_target:
                    self.stats["deadline_spills"] += 1
            return fut
        with self._lock:
            self.stats["rejected"] += 1
        raise BackpressureError(
            f"all {len(self.replicas)} replicas backpressured") from last

    def drain(self) -> List["SearchResponse"]:
        """Serve everything currently queued on every replica; returns the
        responses served since the last drain, across ALL replicas (the
        unified Backend drain contract — pre-PR-5 this returned None while
        the service returned its responses)."""
        out: List[SearchResponse] = []
        for r in self.replicas:
            out.extend(r.drain())
        return out

    # ----------------------------------------------------------- aggregates
    def live_load(self) -> int:
        return sum(r.live_load() for r in self.replicas)

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 over ALL replicas' per-request enqueue->resolve
        latencies (one traffic stream, N servers)."""
        lats = []
        for r in self.replicas:
            with r._lock:
                lats.extend(r.latencies_s)
        if not lats:
            return {"p50": 0.0, "p99": 0.0, "n": 0}
        arr = np.asarray(lats)
        return {"p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99)), "n": len(arr)}

    def stats_rollup(self) -> Dict[str, object]:
        """Router counters + per-replica service stats + the summed
        ``QueryStats`` counters of every response served anywhere."""
        totals = dict.fromkeys(QUERY_STATS_FIELDS, 0)
        per_replica = []
        requests = batches = served = 0
        for r in self.replicas:
            with r._lock:
                per_replica.append(dict(r.stats))
                requests += int(r.stats["requests"])
                batches += int(r.stats["batches"])
                served += r.query_stats["served"]
                for f in QUERY_STATS_FIELDS:
                    totals[f] += r.query_stats[f]
        with self._lock:
            out = {k: (list(v) if isinstance(v, list) else v)
                   for k, v in self.stats.items()}
        out["requests"] = requests
        out["batches"] = batches
        out["served"] = served
        out["query_stats"] = totals
        out["per_replica"] = per_replica
        return out

    def measured_demand(self):
        """Mean per-query :class:`~repro.core.perf_model.QueryDemand` over
        everything SERVED anywhere (cancelled/expired requests contributed
        no stats, so they don't dilute the mean) — the analytic device
        model's input for the replica-scaling sweep
        (``perf_model.qps_at_replicas``)."""
        from repro.core.perf_model import demand_from_stats
        roll = self.stats_rollup()
        return demand_from_stats(
            roll["query_stats"], roll["served"],
            pq_m=self.index.cfg.pq_m,
            dim=self.index.ssd.vectors.shape[1],
            top_m=self.index.cfg.top_m)

    # -------------------------------------------------------------- updates
    def insert(self, vectors: np.ndarray) -> np.ndarray:
        """Insert into the SHARED index: every replica sees the new ids on
        its next dispatch (the executor's HBM placement is keyed on the
        ``codes`` binding, which insert replaces)."""
        return self.index.insert(vectors)

    def delete(self, ids: np.ndarray) -> None:
        """Tombstone ids in the shared DRAM tier — filtered at candidate
        collection by every replica immediately."""
        self.index.delete(ids)
