"""Multi-replica routing over one mesh (the ROADMAP's PR-3 follow-on).

The threaded :class:`~repro.serve.anns_service.BatchingANNSService` is the
per-replica building block: one pump thread + one ticker per replica keeps
a single device group busy.  Serving heavy traffic from one box is then a
ROUTING problem — saturate the whole device tier with many concurrent
query streams.  :class:`ReplicaRouter` fronts N such replicas:

* **one mesh, disjoint device groups** — ``launch.mesh.recarve_mesh``
  carves the shared mesh into N sub-meshes; each replica's
  :class:`~repro.core.executor.QueryExecutor` row-shards the PQ corpus
  over ITS group only (``core.distributed`` commits every scan operand to
  the sub-mesh), so concurrent per-replica ADC scans never contend for a
  chip.  Without a mesh (tests, 1-device hosts) every replica runs
  unsharded on the default device and the router is a pure concurrency
  layer.
* **ELASTIC replica set** — ``add_replica()`` / ``remove_replica()`` grow
  and shrink the set at runtime (the autoscaler's actuators,
  serve/autoscaler.py).  On every resize the parent mesh is re-carved
  into near-equal groups and each surviving replica's executor is
  re-attached to its new group (``QueryExecutor.attach_mesh`` — the HBM
  shard re-places on the next dispatch).  Removal drains: the victim is
  popped from the routing set first, then its pump serves every queued
  request, so zero futures leak.  Each replica ever created owns a stable
  SLOT id; ``stats["routed"]`` is indexed by slot and only grows, so the
  accounting invariant ``submitted == sum(routed) + rejected`` survives
  any scaling history.
* **same futures-first surface** — ``submit() -> QueryFuture`` with
  ``k``/``top_n``/``deadline_s``, backpressure (a submission rejected by
  every replica raises :class:`BackpressureError`), graceful fan-out
  ``stop()`` drain, aggregated ``latency_percentiles()`` and a
  ``QueryStats`` rollup (both include retired replicas' history).
* **pluggable policies** —

  ============= =========================================================
  policy        choice per request
  ============= =========================================================
  round_robin   cycle through replicas (stateless, cache-friendly)
  jsq           join-shortest-queue: each replica's LIVE request count
                (``BatchingANNSService.live_load()`` — uncancelled queued
                + in-flight) picks the least-loaded replica
  deadline      round-robin baseline, but a request carrying a deadline
                spills to the least-loaded replica when that is strictly
                less loaded than the round-robin pick
  ============= =========================================================

  Every policy also SPILLS on backpressure: when the chosen replica's
  queue is full the router tries the remaining replicas (least-loaded
  first) before rejecting.  A spill chain that exhausts EVERY replica
  counts as ``spill_exhausted`` and rejects.
* **update propagation** — founding replicas share ONE segmented index
  object, so ``router.insert()/delete()/compact()`` publish a new
  epoch-stamped :class:`~repro.core.segments.IndexView` that every
  replica's executor pins at its next dispatch (``test_updates``
  semantics hold under routing).  With ``snapshot_dir=`` set,
  ``add_replica()`` HYDRATES the newcomer from a fresh
  ``save_snapshot()`` of the live index instead of sharing it; the
  router then fans every mutation out to each distinct index in the
  same order, and because delta append / tombstone / compaction are
  deterministic, hydrated replicas stay in id-for-id lockstep with the
  donor (mutate through the ROUTER, not a bare index, once a hydrated
  replica exists).

Routing never changes results: each replica runs the same unified
executor pipeline over the same index, so ids are bit-identical to a
single-replica ``run()`` under every policy (tests/test_router.py).
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.analysis.concurrency.witness import make_lock
from repro.core.engine import FusionANNSIndex
from repro.core.executor import QUERY_STATS_FIELDS
from repro.core.futures import BackpressureError, QueryFuture
from repro.serve.anns_service import BatchingANNSService
from repro.serve.client import SearchRequest, SearchResponse

__all__ = ["ReplicaRouter", "POLICIES"]

POLICIES = ("round_robin", "jsq", "deadline")

# retired-replica latency history kept for percentile aggregation (bounded:
# removal must not leak memory over a long autoscaling life)
_RETIRED_LATENCIES_MAX = 4096


class ReplicaRouter:
    """Fronts an elastic set of serving replicas with one futures-first
    ``submit()``."""

    def __init__(self, index: FusionANNSIndex, *, n_replicas: int = 2,
                 policy: str = "jsq", mesh=None, threaded: bool = True,
                 snapshot_dir: Optional[str] = None, **svc_kw):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; one of {POLICIES}")
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.index = index
        # with a snapshot dir, scale-ups hydrate a PRIVATE index from disk
        # (save_snapshot -> load_snapshot) instead of sharing ``index``
        self.snapshot_dir = snapshot_dir
        self.policy = policy
        self.parent_mesh = mesh
        self._lock = make_lock("router")
        if mesh is not None:
            from repro.launch.mesh import recarve_mesh
            self.meshes = recarve_mesh(mesh, n_replicas)  # guarded-by: _lock
        else:
            self.meshes = [None] * n_replicas         # guarded-by: _lock
        # per-replica service knobs, kept so elastically added replicas are
        # configured identically to the founding set
        self._svc_kw = dict(svc_kw)
        # surfaced for coalescing keys (serve/edge.py): these two plan knobs
        # change result ids, so the edge must fold them into the dedup key
        self.fused = bool(svc_kw.get("fused", False))
        self.lut_int8 = bool(svc_kw.get("lut_int8", False))
        # each replica: own executor (own sub-mesh, own dispatch lock, own
        # HBM placement) wrapped by its own pump/ticker service
        self.replicas: List[BatchingANNSService] = [
            BatchingANNSService(index, executor=index.make_executor(m),
                                threaded=threaded, **svc_kw)
            for m in self.meshes]              # guarded-by: _lock
        # per-replica index binding, parallel to ``replicas`` (founding
        # replicas share ``index``; snapshot-hydrated ones own a private
        # copy that mutations fan out to)
        self.indexes: List[FusionANNSIndex] = [
            index] * n_replicas                # guarded-by: _lock
        # stable slot ids, parallel to ``replicas``; slots are never reused
        self.replica_ids: List[int] = list(range(n_replicas))  # guarded-by: _lock
        self._next_slot = n_replicas           # guarded-by: _lock
        # mirrors the replicas' harness (clients read this to pick their
        # backpressure strategy: sleep-retry vs pump-on-behalf)
        self.threaded = threaded
        self._rr = 0       # round-robin cursor; guarded-by: _lock
        self.stats: Dict[str, object] = {
            "submitted": 0, "rejected": 0, "spills": 0,
            "deadline_spills": 0, "spill_exhausted": 0,
            "scale_ups": 0, "scale_downs": 0,
            "routed": [0] * n_replicas}        # guarded-by: _lock
        # removed replicas' history — percentiles and the QueryStats rollup
        # must describe the whole traffic stream, not just survivors
        self._retired_latencies: deque = deque(
            maxlen=_RETIRED_LATENCIES_MAX)     # guarded-by: _lock
        self._retired_query_stats = dict.fromkeys(
            QUERY_STATS_FIELDS, 0)             # guarded-by: _lock
        self._retired = {"requests": 0, "batches": 0, "served": 0,
                         "replicas": []}       # guarded-by: _lock

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ReplicaRouter":
        # snapshot: a concurrent add/remove must not mutate mid-iteration
        with self._lock:
            reps = list(self.replicas)
        for r in reps:
            r.start()
        self.threaded = True
        return self

    def stop(self) -> "ReplicaRouter":
        """Graceful fan-out drain: every replica's pump thread serves its
        remaining queue (zero pending futures survive), in parallel."""
        with self._lock:
            reps = list(self.replicas)
        ts = [threading.Thread(target=r.stop) for r in reps]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        self.threaded = False
        return self

    def __enter__(self) -> "ReplicaRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -------------------------------------------------------------- scaling
    @property
    def n_replicas(self) -> int:
        with self._lock:
            return len(self.replicas)

    def _recarve_locked(self) -> None:            # holds: _lock
        """Re-attach every replica's executor to its share of a fresh carve
        of the parent mesh (no-op without one).  Caller holds ``_lock``."""
        if self.parent_mesh is None:
            self.meshes = [None] * len(self.replicas)
            return
        from repro.launch.mesh import recarve_mesh
        self.meshes = recarve_mesh(self.parent_mesh, len(self.replicas))
        for svc, m in zip(self.replicas, self.meshes):
            svc.executor.attach_mesh(m)

    def add_replica(self) -> int:
        """Grow the replica set by one: re-carve the parent mesh over
        ``n+1`` groups, re-attach the survivors, and start a fresh replica
        (same service knobs as the founding set) on the last group.
        Returns the new replica's stable slot id.

        With ``snapshot_dir`` set the newcomer HYDRATES from disk
        (DESIGN.md §10): the live index is checkpointed via
        ``save_snapshot`` and the replica serves a ``load_snapshot`` copy
        — bit-identical ids at the captured epoch, no re-cluster /
        re-encode, and no shared mutable state with the donor; subsequent
        ``router.insert()/delete()/compact()`` fan out to keep it in
        lockstep."""
        with self._lock:
            # hydration happens INSIDE the router lock on purpose: the
            # mutation fan-out also runs under it, so no insert/delete can
            # land between the checkpoint and the newcomer joining
            # ``self.indexes`` (which would be silently missing from the
            # hydrated copy forever).  router > compaction in the lock
            # hierarchy, so save_snapshot's pin underneath is legal.
            if self.snapshot_dir is not None:
                self.index.save_snapshot(self.snapshot_dir)
                new_index = FusionANNSIndex.load_snapshot(self.snapshot_dir)
            else:
                new_index = self.index
            new = BatchingANNSService(
                new_index, executor=new_index.make_executor(None),
                threaded=False, **self._svc_kw)
            slot = self._next_slot
            self._next_slot += 1
            self.replicas.append(new)
            self.indexes.append(new_index)
            self.replica_ids.append(slot)
            self.stats["routed"].append(0)
            self.stats["scale_ups"] += 1
            self._recarve_locked()
        if self.threaded:
            new.start()
        return slot

    def remove_replica(self, slot: Optional[int] = None, *,
                       drain: bool = True) -> int:
        """Shrink by one: pop the victim from the routing set (new traffic
        stops landing on it immediately), re-carve the survivors over the
        freed devices, then stop the victim — its pump drains every queued
        request before exiting, so zero futures leak.  ``slot`` picks the
        victim (default: the least-loaded replica).  Returns the removed
        slot id.  ``drain=False`` skips the stop (the caller owns it)."""
        with self._lock:
            if len(self.replicas) <= 1:
                raise ValueError("cannot remove the last replica")
            if slot is None:
                loads = [r.live_load()            # acquires: service
                         for r in self.replicas]
                i = min(range(len(loads)), key=lambda j: (loads[j], j))
            else:
                try:
                    i = self.replica_ids.index(slot)
                except ValueError:
                    raise ValueError(f"no replica with slot id {slot}") \
                        from None
            victim = self.replicas.pop(i)
            self.indexes.pop(i)
            slot = self.replica_ids.pop(i)
            self.stats["scale_downs"] += 1
            # keep the round-robin cursor in range after the shrink
            self._rr %= len(self.replicas)
            self._recarve_locked()
        if drain:
            victim.stop()        # pump serves its remaining queue
        # fold the victim's history into the retired accumulators so
        # percentiles/rollups keep describing the full traffic stream
        with victim._lock:                        # acquires: service
            lats = list(victim.latencies_s)
            vstats = dict(victim.stats)
            vqs = dict(victim.query_stats)
        with self._lock:
            self._retired_latencies.extend(lats)
            self._retired["requests"] += int(vstats["requests"])
            self._retired["batches"] += int(vstats["batches"])
            self._retired["served"] += int(vqs["served"])
            self._retired["replicas"].append({"slot": slot, **vstats})
            for f in QUERY_STATS_FIELDS:
                self._retired_query_stats[f] += vqs[f]
        return slot

    def scaling_signals(self) -> Dict[str, object]:
        """One coherent sample of everything the autoscaler keys on:
        aggregate + per-replica live load, the spill/reject counters
        (demand the current set could not place), and queue-latency
        percentiles over the whole stream."""
        with self._lock:
            reps = list(self.replicas)
            spills = int(self.stats["spills"])
            exhausted = int(self.stats["spill_exhausted"])
            rejected = int(self.stats["rejected"])
            submitted = int(self.stats["submitted"])
        loads = [r.live_load() for r in reps]
        pct = self.latency_percentiles()
        return {"n_replicas": len(reps), "live_load": sum(loads),
                "per_replica_load": loads, "submitted": submitted,
                "spills": spills, "spill_exhausted": exhausted,
                "rejected": rejected, "p50": pct["p50"], "p99": pct["p99"],
                "latency_n": pct["n"]}

    # --------------------------------------------------------------- routing
    def _route_order(self, replicas: Sequence[BatchingANNSService],
                     deadline_s: Optional[float]
                     ) -> tuple[Sequence[int], Optional[int]]:
        """Replica indices to try (primary choice first) plus the
        deadline-spill target, if this request jumped the round-robin
        line.  Fallbacks (the backpressure spill path) go least-loaded
        first."""
        n = len(replicas)
        if n == 1:
            return (0,), None
        loads = [r.live_load() for r in replicas]
        by_load = sorted(range(n), key=lambda i: (loads[i], i))
        if self.policy == "jsq":
            return by_load, None
        with self._lock:
            start = self._rr % n
            self._rr = (start + 1) % n
        if self.policy == "deadline" and deadline_s is not None:
            least = by_load[0]
            if loads[least] < loads[start]:
                # deadline-aware spill: tight-deadline traffic jumps to
                # the least-loaded replica instead of waiting in line
                return ([least] + [i for i in by_load if i != least],
                        least)
        # primary = the round-robin pick; backpressure fallbacks go
        # least-loaded first (the documented spill order)
        return [start] + [i for i in by_load if i != start], None

    def submit(self, request: SearchRequest) -> QueryFuture:
        """Route one request; returns the serving replica's future (same
        surface as ``BatchingANNSService.submit`` — a typed
        :class:`~repro.serve.client.SearchRequest` in, a future resolving
        to a :class:`~repro.serve.client.SearchResponse` out).  Tries
        the policy's choice first, spills across the remaining replicas on
        backpressure, and raises :class:`BackpressureError` only when
        EVERY replica's queue is full.  Every call is counted:
        ``submitted == sum(routed) + rejected`` always holds."""
        if not isinstance(request, SearchRequest):
            raise TypeError(
                "submit() takes a SearchRequest; wrap raw query vectors "
                "with as_request(...) or use ANNSClient "
                f"(got {type(request).__name__})")
        req = request
        # snapshot the replica set: a concurrent remove_replica() must not
        # shift indices under the routing loop (the victim still drains any
        # request that raced onto it, so nothing leaks either way)
        with self._lock:
            replicas = list(self.replicas)
            slots = list(self.replica_ids)
            self.stats["submitted"] += 1
        order, dl_target = self._route_order(replicas, req.deadline_s)
        last: Optional[BackpressureError] = None
        for pos, i in enumerate(order):
            try:
                fut = replicas[i].submit(req)
            except BackpressureError as exc:
                last = exc
                continue
            with self._lock:
                self.stats["routed"][slots[i]] += 1
                if pos:
                    self.stats["spills"] += 1
                # counted only when the request actually LANDED on the
                # spill target (not when the spill was merely chosen)
                if dl_target is not None and i == dl_target:
                    self.stats["deadline_spills"] += 1
            return fut
        with self._lock:
            self.stats["rejected"] += 1
            if len(order) > 1:
                # the spill chain visited every replica and none had room
                self.stats["spill_exhausted"] += 1
        raise BackpressureError(
            f"all {len(replicas)} replicas backpressured") from last

    def drain(self) -> List["SearchResponse"]:
        """Serve everything currently queued on every replica; returns the
        responses served since the last drain, across ALL replicas (the
        unified Backend drain contract — pre-PR-5 this returned None while
        the service returned its responses)."""
        out: List[SearchResponse] = []
        with self._lock:
            reps = list(self.replicas)
        for r in reps:
            out.extend(r.drain())
        return out

    # ----------------------------------------------------------- aggregates
    def live_load(self) -> int:
        with self._lock:
            reps = list(self.replicas)
        return sum(r.live_load() for r in reps)

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p99 over ALL replicas' per-request enqueue->resolve
        latencies (one traffic stream, N servers — retired replicas'
        recent history included)."""
        with self._lock:
            reps = list(self.replicas)
            lats = list(self._retired_latencies)
        for r in reps:
            with r._lock:                         # acquires: service
                lats.extend(r.latencies_s)
        if not lats:
            return {"p50": 0.0, "p99": 0.0, "n": 0}
        arr = np.asarray(lats)
        return {"p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99)), "n": len(arr)}

    def stats_rollup(self) -> Dict[str, object]:
        """Router counters + per-replica service stats + the summed
        ``QueryStats`` counters of every response served anywhere —
        including on replicas that have since been removed."""
        with self._lock:
            reps = list(self.replicas)
            totals = dict(self._retired_query_stats)
            requests = self._retired["requests"]
            batches = self._retired["batches"]
            served = self._retired["served"]
            per_replica = [dict(d) for d in self._retired["replicas"]]
        for r in reps:
            with r._lock:                         # acquires: service
                per_replica.append(dict(r.stats))
                requests += int(r.stats["requests"])
                batches += int(r.stats["batches"])
                served += r.query_stats["served"]
                for f in QUERY_STATS_FIELDS:
                    totals[f] += r.query_stats[f]
        with self._lock:
            out = {k: (list(v) if isinstance(v, list) else v)
                   for k, v in self.stats.items()}
        out["requests"] = requests
        out["batches"] = batches
        out["served"] = served
        out["query_stats"] = totals
        out["per_replica"] = per_replica
        return out

    def measured_demand(self):
        """Mean per-query :class:`~repro.core.perf_model.QueryDemand` over
        everything SERVED anywhere (cancelled/expired requests contributed
        no stats, so they don't dilute the mean) — the analytic device
        model's input for the replica-scaling sweep
        (``perf_model.qps_at_replicas``)."""
        from repro.core.perf_model import demand_from_stats
        roll = self.stats_rollup()
        return demand_from_stats(
            roll["query_stats"], roll["served"],
            pq_m=self.index.cfg.pq_m,
            dim=self.index.ssd.vectors.shape[1],
            top_m=self.index.cfg.top_m)

    # -------------------------------------------------------------- updates
    @property
    def epoch(self) -> int:
        """The primary index's segment-list epoch (coalescing keys)."""
        return self.index.epoch

    def _distinct_indexes_locked(self) -> List[FusionANNSIndex]:  # holds: _lock
        seen: set = set()
        out: List[FusionANNSIndex] = []
        for ix in [self.index] + list(self.indexes):
            if id(ix) not in seen:
                seen.add(id(ix))
                out.append(ix)
        return out

    def insert(self, vectors: np.ndarray,
               attributes=None) -> np.ndarray:
        """Append to every distinct index's delta segment (founding
        replicas share one; snapshot-hydrated replicas own copies kept in
        lockstep by this fan-out).  Each replica's executor pins the new
        epoch's view at its next dispatch.  ``attributes`` maps column
        name -> per-row metadata ints (DESIGN.md §11), carried to every
        index identically.  Returns the new global ids (identical on
        every index by determinism)."""
        vecs = np.atleast_2d(np.asarray(vectors, np.float32))
        with self._lock:
            ids = None
            for ix in self._distinct_indexes_locked():
                out = ix.insert(vecs, attributes=attributes)
                ids = out if ids is None else ids
        return ids

    def delete(self, ids: np.ndarray) -> None:
        """Tombstone ids in the owning segment of every distinct index —
        filtered at candidate collection by every replica from its next
        pinned view."""
        with self._lock:
            for ix in self._distinct_indexes_locked():
                ix.delete(ids)

    def compact(self, *, wait: bool = True) -> int:
        """Seal every distinct index's delta into its immutable tiers
        (same deterministic op on each, so hydrated replicas stay
        bit-identical).  Returns rows sealed on the primary index."""
        with self._lock:
            sealed = 0
            for ix in self._distinct_indexes_locked():
                n = ix.compact(wait=wait)
                if ix is self.index:
                    sealed = n
        return sealed
