import json, sys
def load(p):
    return {(r['arch'], r['shape'], r['mesh']): r
            for r in map(json.loads, open(p)) if r.get('ok')}
a = load(sys.argv[1]); b = load(sys.argv[2])
keys = sorted(set(a) & set(b))
for k in keys:
    ra, rb = a[k]['roofline'], b[k]['roofline']
    da = max(ra['t_compute_s'], ra['t_memory_s'], ra['t_collective_s'])
    db = max(rb['t_compute_s'], rb['t_memory_s'], rb['t_collective_s'])
    if abs(da - db) / max(da, 1e-12) > 0.03 or \
       abs(ra['t_collective_s'] - rb['t_collective_s']) / max(ra['t_collective_s'], 1e-12) > 0.05:
        print(f"{k[0]:20s} {k[1]:14s} {k[2]:6s} dom {da:.3e}->{db:.3e} "
              f"coll {ra['t_collective_s']:.3e}->{rb['t_collective_s']:.3e} "
              f"mem {ra['t_memory_s']:.3e}->{rb['t_memory_s']:.3e} "
              f"peak {a[k]['memory']['peak_bytes_per_device']/2**30:.2f}->{b[k]['memory']['peak_bytes_per_device']/2**30:.2f}GiB")
