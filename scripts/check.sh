#!/usr/bin/env bash
# Tier-1 gate + smoke targets.
#
#   scripts/check.sh            tier-1: full default suite (slow deselected
#                               via pytest.ini), no pytest cache, hard
#                               wall-clock guard
#   scripts/check.sh smoke      fast executor/engine subset (used by
#                               benchmarks/run.py --selftest)
#   scripts/check.sh threaded-stress
#                               threaded serving runtime: 8 producer
#                               threads against one replica, id-parity
#                               with run(), out-of-order retirement
#                               probe, zero leaked pending futures
#   scripts/check.sh router-stress
#                               multi-replica routing: policy id-parity,
#                               8 producers across 2 replicas, JSQ
#                               saturation bypass, sub-mesh scan parity,
#                               deterministic fault injection
#   scripts/check.sh async-stress
#                               unified client API: Backend protocol
#                               conformance, 4-path id parity, 200
#                               concurrent asyncio coroutines over a
#                               2-replica router, awaited-admission
#                               backpressure, zero leaked futures
#   scripts/check.sh kernels    kernel parity tests + micro-benchmarks;
#                               persists BENCH_kernels.json and fails on
#                               rows slower than BENCH_REGRESSION_FACTOR
#                               (default 1.6) x the previous artifact
#   scripts/check.sh edge-stress
#                               HTTP edge + autoscaler: auth/rate-limit/
#                               error codes over a real socket, coalesced
#                               burst = one backend submit, 200-connection
#                               soak, deterministic load-ramp (scale up
#                               under burst, drain on scale-down, zero
#                               leaked futures at router AND edge level)
#   scripts/check.sh filter-stress
#                               filtered + multi-tenant search: filtered
#                               top-k vs the exact post-filter oracle
#                               (selectivity sweep, delta-only matches,
#                               tombstones, snapshots), tenant quota
#                               enforcement and base-predicate stamping,
#                               socket-level cross-tenant isolation, and
#                               the deadline-adaptive resolver — all
#                               under LINT_LOCKS=1 witnesses
#   scripts/check.sh mutate-stress
#                               updates-while-serving: insert/delete
#                               bursts + background compaction against
#                               the threaded service and a 2-replica
#                               router with a snapshot-hydrated newcomer;
#                               bit-identical ids vs a quiesced serial
#                               replay, snapshot->restore parity, zero
#                               leaked futures, zero witnessed lock-order
#                               violations (LINT_LOCKS=1)
#   scripts/check.sh lint       concurrency static analysis over src/:
#                               guarded-by checker (GB*), lock-order
#                               deadlock detector (LO*), jit/hot-path
#                               purity lints (PU*).  Zero findings or
#                               non-zero exit.  See DESIGN.md §9.
#   scripts/check.sh fig9       throughput/latency figure as a ratchet:
#                               persists BENCH_fig9.json (incl. the
#                               edge_http socket row) and fails on rows
#                               slower than BENCH_REGRESSION_FACTOR x the
#                               previous artifact.  Scale pinned via
#                               REPRO_BENCH_N / REPRO_BENCH_QUERIES so
#                               the committed artifact and CI agree.
#   scripts/check.sh full       everything, including @slow system tests
#
# CHECK_TIMEOUT overrides the guard (seconds).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE="${1:-tier1}"
case "$MODE" in
  smoke)
    exec timeout "${CHECK_TIMEOUT:-420}" \
      python -m pytest -x -q -p no:cacheprovider \
        tests/test_executor.py tests/test_futures.py tests/test_engine.py \
        tests/test_updates.py tests/test_threaded.py tests/test_client.py
    ;;
  lint)
    exec timeout "${CHECK_TIMEOUT:-120}" \
      python -m repro.analysis.concurrency --check src/
    ;;
  threaded-stress)
    # LINT_LOCKS=1: serving-stack locks become OrderedLock witnesses —
    # any runtime lock-order inversion fails the offending test
    export LINT_LOCKS="${LINT_LOCKS:-1}"
    exec timeout "${CHECK_TIMEOUT:-300}" \
      python -m pytest -x -q -p no:cacheprovider tests/test_threaded.py
    ;;
  async-stress)
    exec timeout "${CHECK_TIMEOUT:-300}" \
      python -m pytest -x -q -p no:cacheprovider tests/test_client.py
    ;;
  router-stress)
    export LINT_LOCKS="${LINT_LOCKS:-1}"
    exec timeout "${CHECK_TIMEOUT:-600}" \
      python -m pytest -x -q -p no:cacheprovider tests/test_router.py \
        tests/test_faults.py
    ;;
  mutate-stress)
    export LINT_LOCKS="${LINT_LOCKS:-1}"
    exec timeout "${CHECK_TIMEOUT:-600}" \
      python -m pytest -x -q -p no:cacheprovider \
        tests/test_mutate_stress.py tests/test_segments.py \
        tests/test_updates.py
    ;;
  filter-stress)
    export LINT_LOCKS="${LINT_LOCKS:-1}"
    exec timeout "${CHECK_TIMEOUT:-600}" \
      python -m pytest -x -q -p no:cacheprovider tests/test_filters.py \
        tests/test_tenants.py tests/test_edge.py
    ;;
  kernels)
    timeout "${CHECK_TIMEOUT:-600}" \
      python -m pytest -x -q -p no:cacheprovider tests/test_kernels.py \
        tests/test_kernel_props.py
    exec timeout "${CHECK_TIMEOUT:-600}" \
      python -m benchmarks.run --only kernels --persist
    ;;
  edge-stress)
    export LINT_LOCKS="${LINT_LOCKS:-1}"
    exec timeout "${CHECK_TIMEOUT:-600}" \
      python -m pytest -x -q -p no:cacheprovider tests/test_edge.py \
        tests/test_autoscaler.py tests/test_coalesce.py
    ;;
  fig9)
    export REPRO_BENCH_N="${REPRO_BENCH_N:-12000}"
    export REPRO_BENCH_QUERIES="${REPRO_BENCH_QUERIES:-32}"
    exec timeout "${CHECK_TIMEOUT:-900}" \
      python -m benchmarks.run --only fig9 --persist
    ;;
  tier1)
    exec timeout "${CHECK_TIMEOUT:-600}" \
      python -m pytest -x -q -p no:cacheprovider
    ;;
  full)
    exec timeout "${CHECK_TIMEOUT:-1800}" \
      python -m pytest -x -q -p no:cacheprovider -m ""
    ;;
  *)
    echo "usage: scripts/check.sh [tier1|smoke|lint|threaded-stress|router-stress|async-stress|mutate-stress|filter-stress|kernels|edge-stress|fig9|full]" >&2
    exit 2
    ;;
esac
