"""Fig. 3: SPANN throughput saturates at few threads (SSD bandwidth-bound);
latency split graph-vs-postinglist grows with threads."""

import numpy as np

from benchmarks.common import HW, bundle
from repro.core.baselines import SpannLike
from repro.core.perf_model import sweep_threads


def run():
    b = bundle("sift")
    spann = SpannLike(b.index, b.data)
    res = [spann.query(q, 10, b.cfg.top_m) for q in b.queries]
    demand = res[0].demand
    for r in res[1:]:
        for f in ("ssd_ios", "ssd_bytes", "cpu_dist_ops", "graph_hops"):
            setattr(demand, f, getattr(demand, f) + getattr(r.demand, f))
    for f in ("ssd_ios", "ssd_bytes", "cpu_dist_ops", "graph_hops"):
        setattr(demand, f, getattr(demand, f) / len(res))
    sweep = sweep_threads(demand, HW)
    rows = []
    peak_t = max(sweep, key=lambda t: sweep[t]["qps"])
    for t, v in sweep.items():
        rows.append({
            "name": f"fig3.spann.threads{t}",
            "us_per_call": v["latency_ms"] * 1e3,
            "derived": f"qps={v['qps']:.0f}",
        })
    rows.append({"name": "fig3.spann.peak_threads", "us_per_call": 0,
                 "derived": f"peak_at_threads={peak_t} "
                            f"(paper: ~4; bandwidth-bound "
                            f"bytes/q={demand.ssd_bytes:.0f})"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
