"""Fig. 4: the straw-man combinations (HI, HI+GPU, HI+PQ, HI+PQ+GPU):
latency breakdown (a), QPS (b), I/O counts (c), CPU<->GPU volume (d)."""

import numpy as np

from benchmarks.common import HW, bundle, fusion_demand
from repro.core.baselines import HIGpu, HIPq, SpannLike
from repro.core.engine import recall_at_k
from repro.core.perf_model import qps_at_threads, single_thread_latency


def run():
    b = bundle("sift")
    systems = {
        "HI": lambda q: SpannLike(b.index, b.data).query(q, 10, b.cfg.top_m),
    }
    spann = SpannLike(b.index, b.data)
    higpu = HIGpu(b.index, b.data)
    hipq = HIPq(b.index, b.data, gpu=False)
    hipqgpu = HIPq(b.index, b.data, gpu=True)

    rows = []
    agg = {}
    for name, sysq in [("HI", spann), ("HI+GPU", higpu)]:
        res = [sysq.query(q, 10, b.cfg.top_m) for q in b.queries]
        agg[name] = res
    for name, sysq in [("HI+PQ", hipq), ("HI+PQ+GPU", hipqgpu)]:
        res = [sysq.query(q, 10, b.cfg.top_m, b.cfg.top_n)
               for q in b.queries]
        agg[name] = res
    fus = fusion_demand(b.index, b.queries)
    rec_f = recall_at_k(np.stack([r.ids for r in fus["results"]]), b.gt, 10)

    for name, res in agg.items():
        d = res[0].demand
        n = len(res)
        mean = lambda f: float(np.mean([getattr(r.demand, f) for r in res]))
        from repro.core.perf_model import QueryDemand
        dm = QueryDemand(**{f: mean(f) for f in (
            "ssd_ios", "ssd_bytes", "h2d_bytes", "gpu_lookups",
            "cpu_lookups", "cpu_dist_ops", "graph_hops")})
        lat = single_thread_latency(dm, HW)
        qps = qps_at_threads(dm, HW, 64)
        rec = recall_at_k(np.stack([r.ids for r in res]), b.gt, 10)
        rows.append({
            "name": f"fig4.{name}",
            "us_per_call": lat * 1e6,
            "derived": (f"qps64={qps:.0f} ios={dm.ssd_ios:.1f} "
                        f"ssd_KB={dm.ssd_bytes/1e3:.1f} "
                        f"h2d_KB={dm.h2d_bytes/1e3:.1f} recall={rec:.3f}"),
        })
    dm = fus["demand"]
    lat = single_thread_latency(dm, HW)
    rows.append({
        "name": "fig4.FusionANNS",
        "us_per_call": lat * 1e6,
        "derived": (f"qps64={qps_at_threads(dm, HW, 64):.0f} "
                    f"ios={dm.ssd_ios:.1f} ssd_KB={dm.ssd_bytes/1e3:.1f} "
                    f"h2d_KB={dm.h2d_bytes/1e3:.1f} recall={rec_f:.3f}"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
