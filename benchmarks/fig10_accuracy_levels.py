"""Fig. 10: QPS/latency (normalised to SPANN) at increasing accuracy levels
on the SIFT-like dataset."""

import numpy as np

from benchmarks.common import HW, bundle, fusion_demand, tune_for_recall
from repro.core.baselines import SpannLike
from repro.core.engine import recall_at_k
from repro.core.perf_model import QueryDemand, qps_at_threads


def run():
    b = bundle("sift")
    rows = []
    for target in (0.90, 0.95, 0.98):
        top_m, top_n, rec = tune_for_recall(
            b.index, b.queries, b.gt, target)
        fus = fusion_demand(b.index, b.queries, top_m=top_m, top_n=top_n)
        f_qps = qps_at_threads(fus["demand"], HW, 64)
        # SPANN needs a bigger top_m for the same recall
        sp_m = top_m
        for m in (8, 16, 24, 48, 96):
            res = [SpannLike(b.index, b.data).query(q, 10, m)
                   for q in b.queries]
            if recall_at_k(np.stack([r.ids for r in res]), b.gt, 10) \
                    >= target:
                sp_m = m
                break
        sp = [SpannLike(b.index, b.data).query(q, 10, sp_m)
              for q in b.queries]
        fields = ("ssd_ios", "ssd_bytes", "cpu_dist_ops", "graph_hops")
        dm = QueryDemand(**{f: float(np.mean([getattr(r.demand, f)
                                              for r in sp]))
                            for f in fields})
        s_qps = qps_at_threads(dm, HW, 64)
        rows.append({
            "name": f"fig10.recall{int(target*100)}",
            "us_per_call": 0,
            "derived": (f"fusion_qps={f_qps:.0f} spann_qps={s_qps:.0f} "
                        f"norm={f_qps/max(s_qps,1e-9):.1f}x "
                        f"(top_m={top_m},top_n={top_n},achieved={rec:.3f}; "
                        f"paper: 9.4-11.7x)"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
