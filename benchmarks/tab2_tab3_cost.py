"""Tables 2 & 3: cost efficiency (QPS/$) and memory efficiency (QPS/GB),
using the paper's price book: server $5000, DRAM $10/GB, 2TB SSD $400,
GPU (V100-class accelerator) $3000."""

import numpy as np

from benchmarks.common import HW, bundle, fusion_demand
from repro.core.baselines import RummyLike, SpannLike
from repro.core.perf_model import QueryDemand, qps_at_threads

SERVER = 5000.0
DRAM_PER_GB = 10.0
SSD = 400.0
GPU = 3000.0


def _mean_demand(results):
    fields = ("ssd_ios", "ssd_bytes", "h2d_bytes", "gpu_lookups",
              "cpu_lookups", "cpu_dist_ops", "graph_hops")
    return QueryDemand(**{f: float(np.mean([getattr(r.demand, f)
                                            for r in results]))
                          for f in fields})


def _footprints(b):
    """Memory (DRAM+HBM) footprint per system, scaled from measured
    structures (GB)."""
    idx = b.index
    vec_b = b.data.dtype.itemsize * b.data.shape[1]
    graph_b = idx.graph.neighbors.nbytes + idx.graph.points.nbytes
    meta_b = sum(m.nbytes for m in idx.posting.members)
    codes_b = np.asarray(idx.codes).nbytes
    fusion_mem = (graph_b + meta_b) / 1e9            # host DRAM
    fusion_hbm = codes_b / 1e9
    spann_mem = graph_b / 1e9                        # centroid graph only
    rummy_mem = (graph_b + meta_b) / 1e9 \
        + sum(len(m) for m in idx.posting.members) * vec_b / 1e9
    return {"FusionANNS": (fusion_mem, fusion_hbm),
            "SPANN": (spann_mem, 0.0),
            "RUMMY": (rummy_mem, 32.0 / 1e9 * 0)}    # RUMMY vectors in DRAM


def run():
    b = bundle("sift")
    fus = fusion_demand(b.index, b.queries)
    demands = {
        "FusionANNS": fus["demand"],
        "SPANN": _mean_demand([SpannLike(b.index, b.data)
                               .query(q, 10, b.cfg.top_m)
                               for q in b.queries]),
        "RUMMY": _mean_demand([RummyLike(b.index, b.data)
                               .query(q, 10, b.cfg.top_m)
                               for q in b.queries]),
    }
    mem = _footprints(b)
    # scale footprints to the 1B-vector deployment for the cost book
    scale = 1e9 / b.cfg.n_vectors
    rows = []
    qpsd, memd = {}, {}
    for name, dm in demands.items():
        qps = qps_at_threads(dm, HW, 64)
        dram_gb = mem[name][0] * scale
        hbm_gb = mem[name][1] * scale
        if name == "RUMMY":
            dram_gb = mem[name][0] * scale            # TB-scale host memory
        cost = SERVER + DRAM_PER_GB * max(dram_gb, 64) + SSD
        if name in ("FusionANNS", "RUMMY"):
            cost += GPU
        rows.append({
            "name": f"tab2.{name}",
            "us_per_call": 0,
            "derived": (f"qps_per_dollar={qps/cost:.2f} "
                        f"(qps={qps:.0f}, cost=${cost:.0f}, "
                        f"dram={dram_gb:.0f}GB hbm={hbm_gb:.0f}GB)"),
        })
        total_mem = max(dram_gb, 64) + hbm_gb
        qpsd[name], memd[name] = qps, total_mem
        rows.append({
            "name": f"tab3.{name}",
            "us_per_call": 0,
            "derived": f"qps_per_GB={qps/total_mem:.1f}",
        })
    rows.append({
        "name": "tab2.improvement", "us_per_call": 0,
        "derived": (f"cost_eff_vs_spann="
                    f"{(qpsd['FusionANNS']/memd['FusionANNS'])/(qpsd['SPANN']/memd['SPANN']):.1f}x_memeff "
                    f"(paper: 5.67-8.78x cost, 13.1x mem on SIFT1B)"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
