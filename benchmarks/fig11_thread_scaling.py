"""Fig. 11: QPS + latency vs thread count (1..64) for all four systems."""

import numpy as np

from benchmarks.common import HW, bundle, fusion_demand
from repro.core.baselines import DiskAnnLike, RummyLike, SpannLike
from repro.core.perf_model import QueryDemand, sweep_threads


def _mean_demand(results) -> QueryDemand:
    fields = ("ssd_ios", "ssd_bytes", "h2d_bytes", "gpu_lookups",
              "cpu_lookups", "cpu_dist_ops", "graph_hops")
    return QueryDemand(**{f: float(np.mean([getattr(r.demand, f)
                                            for r in results]))
                          for f in fields})


def run():
    b = bundle("sift")
    diskann = DiskAnnLike(b.data, degree=24)
    fus = fusion_demand(b.index, b.queries)
    demands = {
        "FusionANNS": fus["demand"],
        "SPANN": _mean_demand([SpannLike(b.index, b.data)
                               .query(q, 10, b.cfg.top_m)
                               for q in b.queries]),
        "RUMMY": _mean_demand([RummyLike(b.index, b.data)
                               .query(q, 10, b.cfg.top_m)
                               for q in b.queries]),
        "DiskANN": _mean_demand([diskann.query(q, 10) for q in b.queries]),
    }
    rows = []
    for name, dm in demands.items():
        sweep = sweep_threads(dm, HW)
        curve = " ".join(f"t{t}={v['qps']:.0f}" for t, v in sweep.items())
        peak = max(sweep, key=lambda t: sweep[t]["qps"])
        rows.append({
            "name": f"fig11.{name}",
            "us_per_call": sweep[peak]["latency_ms"] * 1e3,
            "derived": f"peak@t{peak} {curve}",
        })
    f64 = sweep_threads(demands["FusionANNS"], HW)[64]["qps"]
    s64 = sweep_threads(demands["SPANN"], HW)[64]["qps"]
    d64 = sweep_threads(demands["DiskANN"], HW)[64]["qps"]
    r64 = sweep_threads(demands["RUMMY"], HW)[64]["qps"]
    rows.append({"name": "fig11.speedups_at_t64", "us_per_call": 0,
                 "derived": (f"vs_spann={f64/s64:.1f}x vs_diskann={f64/d64:.1f}x "
                             f"vs_rummy={f64/r64:.1f}x "
                             f"(paper: 13.2x / 3.8x / 5.1x)")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
