"""Beyond-paper: fused batched-query scan with inter-query candidate dedup
(engine.query_batch_fused) vs the paper's thread-per-query model."""

import numpy as np

from benchmarks.common import bundle
from repro.core.engine import recall_at_k


def run():
    b = bundle("sift")
    rows = []
    nq = min(32, len(b.queries))
    per = b.index.batch_query(b.queries[:nq])
    fused = b.index.query_batch_fused(b.queries[:nq])
    r_per = recall_at_k(np.stack([r.ids for r in per]), b.gt[:nq], 10)
    r_fused = recall_at_k(np.stack([r.ids for r in fused]), b.gt[:nq], 10)
    scans_per = sum(r.stats.candidates_scanned for r in per)
    scans_fused = fused[0].stats.candidates_scanned      # union, once
    m = b.cfg.pq_m
    rows.append({
        "name": "beyond.fused_batch",
        "us_per_call": 0,
        "derived": (f"recall per={r_per:.3f} fused={r_fused:.3f}; "
                    f"lut_lookups per-query={scans_per*m:.2e} "
                    f"fused-union={scans_fused*m:.2e} "
                    f"(dedup {scans_per/max(scans_fused,1):.1f}x; codes "
                    f"read once per batch via pq_adc_batch kernel)"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
