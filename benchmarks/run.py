"""Benchmark harness: one module per paper table/figure.  Prints
``name,us_per_call,derived`` CSV (harness contract)."""

import argparse
import os
import subprocess
import sys
import time
import traceback

# allow `python -m benchmarks.run` / `python benchmarks/run.py` without a
# PYTHONPATH=src export
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from benchmarks import (beyond_fused_batch, fig3_spann_scaling, fig4_combos,
                        fig5_rerank, fig9_throughput_latency,
                        fig10_accuracy_levels, fig11_thread_scaling,
                        fig12_ablation, kernels_bench, tab2_tab3_cost)

ALL = {
    "fig3": fig3_spann_scaling,
    "fig4": fig4_combos,
    "fig5": fig5_rerank,
    "fig9": fig9_throughput_latency,
    "fig10": fig10_accuracy_levels,
    "fig11": fig11_thread_scaling,
    "fig12": fig12_ablation,
    "tab2_tab3": tab2_tab3_cost,
    "kernels": kernels_bench,
    "beyond": beyond_fused_batch,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=sorted(ALL),
                    help="run a subset of figures")
    ap.add_argument("--selftest", action="store_true",
                    help="run the fast correctness smoke (scripts/check.sh "
                         "smoke); add --only to continue to those figures "
                         "afterwards, else only a selftest row is emitted")
    args = ap.parse_args()
    if args.selftest:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        rc = subprocess.run(
            ["bash", os.path.join(root, "scripts", "check.sh"), "smoke"],
            cwd=root).returncode
        if rc != 0:
            print(f"# selftest FAILED (rc={rc})", file=sys.stderr)
            sys.exit(rc)
        print("# selftest passed", file=sys.stderr)
        if not args.only:                 # keep the CSV contract
            print("name,us_per_call,derived")
            print("selftest,0.0,scripts/check.sh smoke passed")
            return
    names = args.only or list(ALL)
    print("name,us_per_call,derived")
    ok = True
    for name in names:
        t0 = time.time()
        try:
            rows = ALL[name].run()
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']:.1f},{derived}")
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{name},0,ERROR", file=sys.stdout)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
