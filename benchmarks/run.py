"""Benchmark harness: one module per paper table/figure.  Prints
``name,us_per_call,derived`` CSV (harness contract).

``--persist`` additionally writes one ``BENCH_<area>.json`` artifact per
area at the repo root and compares each row's ``us_per_call`` against the
previous artifact: a row slower than ``BENCH_REGRESSION_FACTOR`` (default
1.6x) times its previous value fails the run — the per-PR perf ratchet
scripts/check.sh's ``kernels`` target enforces in CI.

``--profile DIR`` wraps the selected figures in ``jax.profiler.trace``:
one TensorBoard-loadable trace (device dispatches + host annotations)
lands in DIR — see DESIGN.md §11."""

import argparse
import contextlib
import json
import os
import subprocess
import sys
import time
import traceback

# allow `python -m benchmarks.run` / `python benchmarks/run.py` without a
# PYTHONPATH=src export
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from benchmarks import (beyond_fused_batch, fig3_spann_scaling, fig4_combos,
                        fig5_rerank, fig9_throughput_latency,
                        fig10_accuracy_levels, fig11_thread_scaling,
                        fig12_ablation, kernels_bench, tab2_tab3_cost)

ALL = {
    "fig3": fig3_spann_scaling,
    "fig4": fig4_combos,
    "fig5": fig5_rerank,
    "fig9": fig9_throughput_latency,
    "fig10": fig10_accuracy_levels,
    "fig11": fig11_thread_scaling,
    "fig12": fig12_ablation,
    "tab2_tab3": tab2_tab3_cost,
    "kernels": kernels_bench,
    "beyond": beyond_fused_batch,
}


def _persist_and_compare(area: str, rows, root: str,
                         factor: float) -> list:
    """Write BENCH_<area>.json and diff against the previous artifact.
    Returns a list of regression strings (empty = pass).  Rows that are
    new or removed never fail — only a matched name that got slower than
    ``factor`` x its previous us_per_call does."""
    path = os.path.join(root, f"BENCH_{area}.json")
    prev = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = {r["name"]: r for r in json.load(f)["rows"]}
        except (json.JSONDecodeError, KeyError, TypeError):
            prev = {}                     # unreadable artifact: rewrite it
    regressions = []
    for r in rows:
        old = prev.get(r["name"])
        if old and old.get("us_per_call"):
            ratio = r["us_per_call"] / old["us_per_call"]
            if ratio > factor:
                regressions.append(
                    f"{r['name']}: {old['us_per_call']:.1f} -> "
                    f"{r['us_per_call']:.1f} us/call ({ratio:.2f}x, "
                    f"threshold {factor}x)")
    if not regressions:       # keep the old baseline when the run regressed
        with open(path, "w") as f:
            json.dump({"area": area,
                       "rows": [{"name": r["name"],
                                 "us_per_call": r["us_per_call"],
                                 "derived": str(r["derived"])}
                                for r in rows]}, f, indent=1)
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=sorted(ALL),
                    help="run a subset of figures")
    ap.add_argument("--selftest", action="store_true",
                    help="run the fast correctness smoke (scripts/check.sh "
                         "smoke); add --only to continue to those figures "
                         "afterwards, else only a selftest row is emitted")
    ap.add_argument("--persist", action="store_true",
                    help="write BENCH_<area>.json per area and fail on "
                         "rows slower than BENCH_REGRESSION_FACTOR "
                         "(default 1.6) x the previous artifact")
    ap.add_argument("--profile", metavar="DIR",
                    help="wrap the selected figures in jax.profiler.trace"
                         "(DIR): one TensorBoard-loadable trace of every "
                         "device dispatch + host annotation (DESIGN.md "
                         "§11)")
    args = ap.parse_args()
    if args.selftest:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        rc = subprocess.run(
            ["bash", os.path.join(root, "scripts", "check.sh"), "smoke"],
            cwd=root).returncode
        if rc != 0:
            print(f"# selftest FAILED (rc={rc})", file=sys.stderr)
            sys.exit(rc)
        print("# selftest passed", file=sys.stderr)
        if not args.only:                 # keep the CSV contract
            print("name,us_per_call,derived")
            print("selftest,0.0,scripts/check.sh smoke passed")
            return
    names = args.only or list(ALL)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    factor = float(os.environ.get("BENCH_REGRESSION_FACTOR", "1.6"))
    print("name,us_per_call,derived")
    ok = True
    regressions = []
    with contextlib.ExitStack() as stack:
        if args.profile:
            import jax
            os.makedirs(args.profile, exist_ok=True)
            stack.enter_context(jax.profiler.trace(args.profile))
            print(f"# jax profiler tracing to {args.profile} "
                  "(load in TensorBoard)", file=sys.stderr)
        for name in names:
            t0 = time.time()
            try:
                rows = ALL[name].run()
                for r in rows:
                    derived = str(r["derived"]).replace(",", ";")
                    print(f"{r['name']},{r['us_per_call']:.1f},{derived}")
                if args.persist:
                    regressions += _persist_and_compare(name, rows, root,
                                                        factor)
            except Exception:  # noqa: BLE001
                ok = False
                print(f"{name},0,ERROR", file=sys.stdout)
                traceback.print_exc()
            print(f"# {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
    for msg in regressions:
        print(f"# PERF REGRESSION: {msg}", file=sys.stderr)
    if not ok or regressions:
        sys.exit(1)


if __name__ == "__main__":
    main()
