"""Benchmark harness: one module per paper table/figure.  Prints
``name,us_per_call,derived`` CSV (harness contract)."""

import argparse
import sys
import time
import traceback

from benchmarks import (beyond_fused_batch, fig3_spann_scaling, fig4_combos,
                        fig5_rerank, fig9_throughput_latency,
                        fig10_accuracy_levels, fig11_thread_scaling,
                        fig12_ablation, kernels_bench, tab2_tab3_cost)

ALL = {
    "fig3": fig3_spann_scaling,
    "fig4": fig4_combos,
    "fig5": fig5_rerank,
    "fig9": fig9_throughput_latency,
    "fig10": fig10_accuracy_levels,
    "fig11": fig11_thread_scaling,
    "fig12": fig12_ablation,
    "tab2_tab3": tab2_tab3_cost,
    "kernels": kernels_bench,
    "beyond": beyond_fused_batch,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=sorted(ALL),
                    help="run a subset of figures")
    args = ap.parse_args()
    names = args.only or list(ALL)
    print("name,us_per_call,derived")
    ok = True
    for name in names:
        t0 = time.time()
        try:
            rows = ALL[name].run()
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']:.1f},{derived}")
        except Exception:  # noqa: BLE001
            ok = False
            print(f"{name},0,ERROR", file=sys.stdout)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
