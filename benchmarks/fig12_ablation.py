"""Fig. 12: individual-technique ablation — MI(CPU), MI(GPU), +HR,
+redundancy-aware dedup: QPS, latency, and I/Os per query."""

import dataclasses

import numpy as np

from benchmarks.common import HW, bundle, fusion_demand
from repro.core.baselines import SpannLike
from repro.core.engine import FusionANNSIndex
from repro.core.io_sim import SSDSim
from repro.core.perf_model import (QueryDemand, qps_at_threads,
                                   single_thread_latency)


def _variant(index, *, intra, buf):
    return FusionANNSIndex(
        cfg=index.cfg, codebook=index.codebook, codes=index.codes,
        posting=index.posting, graph=index.graph,
        ssd=SSDSim(index.ssd.vectors, index.ssd.layout,
                   buffer_pages=index.cfg.dram_buffer_pages,
                   intra_merge=intra, use_buffer=buf))


def run():
    b = bundle("sift")
    rows = []

    def record(name, demand, note=""):
        lat = single_thread_latency(demand, HW)
        rows.append({
            "name": f"fig12.{name}",
            "us_per_call": lat * 1e6,
            "derived": (f"qps64={qps_at_threads(demand, HW, 64):.0f} "
                        f"ios={demand.ssd_ios:.1f} {note}"),
        })
        return qps_at_threads(demand, HW, 64), demand.ssd_ios

    # SPANN reference
    sp = [SpannLike(b.index, b.data).query(q, 10, b.cfg.top_m)
          for q in b.queries]
    fields = ("ssd_ios", "ssd_bytes", "cpu_dist_ops", "graph_hops")
    spd = QueryDemand(**{f: float(np.mean([getattr(r.demand, f)
                                           for r in sp])) for f in fields})
    q_sp, io_sp = record("SPANN", spd)

    # MI only (no heuristic early-stop, no dedup); CPU vs GPU ADC placement
    plain = _variant(b.index, intra=False, buf=False)
    mi = fusion_demand(plain, b.queries, disable_early_stop=True)
    d = mi["demand"]
    d_cpu = dataclasses.replace(d, cpu_lookups=d.gpu_lookups, gpu_lookups=0.0,
                                h2d_bytes=0.0)
    q_micpu, _ = record("MI_CPU", d_cpu, "(ADC on CPU)")
    q_migpu, io_mi = record("MI_GPU", d, "(ADC on accelerator)")

    # + heuristic re-ranking
    hr = fusion_demand(_variant(b.index, intra=False, buf=False), b.queries)
    q_hr, io_hr = record("MI_GPU+HR", hr["demand"])

    # + redundancy-aware dedup (full FusionANNS)
    full = fusion_demand(b.index, b.queries)
    q_full, io_full = record("FusionANNS", full["demand"])

    rows.append({
        "name": "fig12.deltas", "us_per_call": 0,
        "derived": (f"MI_io_reduction={io_sp/max(io_mi,1e-9):.1f}x "
                    f"(paper 3.2-3.8x) "
                    f"HR_io=-{100*(1-io_hr/max(io_mi,1e-9)):.0f}% (paper -30%) "
                    f"dedup_io=-{100*(1-io_full/max(io_hr,1e-9)):.0f}% "
                    f"(paper -23%) "
                    f"MI_GPU_vs_SPANN_qps={q_migpu/max(q_sp,1e-9):.1f}x "
                    f"(paper 5.9-6.8x)"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
