"""Kernel micro-benchmarks: us/call of the ADC scan + exact-L2 oracle paths
(jnp on CPU; Pallas interpret path checked for parity, not speed)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.l2dist import l2_distances
from repro.kernels.pq_adc import (pq_adc, pq_adc_fused_topk, pq_adc_topk,
                                  pq_adc_topk_batch)


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []
    for n, m in [(65536, 32), (262144, 32)]:
        codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
        lut = jnp.asarray(rng.random((m, 256)), jnp.float32)
        us = _time(lambda c, l: pq_adc(c, l, use_kernel=False), codes, lut)
        lookups_per_s = n * m / (us / 1e6)
        rows.append({"name": f"kern.pq_adc.n{n}", "us_per_call": us,
                     "derived": f"lookups_per_s={lookups_per_s:.2e}"})
        us = _time(lambda c, l: pq_adc_topk(c, l, 256, use_kernel=False),
                   codes, lut)
        rows.append({"name": f"kern.pq_adc_topk.n{n}", "us_per_call": us,
                     "derived": "fused scan+topk (jnp path)"})
    # the executor's windowed scan at fig9's default shapes: B queries
    # amortise one pass over the codes; the mask is the per-query
    # candidate membership (stage ⑤).  The fused row runs the SAME query
    # set through the ISSUE-6 LUT→ADC→top-k pipeline (per-query candidate
    # row lists instead of a dense mask) and must return bit-identical
    # top-k (dist, id) pairs at ≥ 2x the unfused wall-clock.
    m, b, topk = 32, 8, 256
    dsub = 4
    cb = jnp.asarray(rng.standard_normal((m, 256, dsub)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((b, m * dsub)), jnp.float32)
    from repro.kernels.pq_adc import build_luts_ref
    luts = jax.jit(build_luts_ref)(cb, queries)
    for n in (65536, 262144):
        codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
        mask_np = rng.random((b, n)) < 0.1
        mask = jnp.asarray(mask_np)
        s = 1 << int(np.ceil(np.log2(mask_np.sum(1).max())))
        rows_np = np.full((b, s), -1, np.int32)
        for qi in range(b):
            ids_q = np.where(mask_np[qi])[0]          # ascending
            rows_np[qi, :len(ids_q)] = ids_q
        cand_rows = jnp.asarray(rows_np)
        us_unfused = _time(
            lambda c, l, mk: pq_adc_topk_batch(c, l, topk, mask=mk,
                                               use_kernel=False),
            codes, luts, mask)
        rows.append({"name": f"kern.pq_adc_topk_batch.b{b}.n{n}",
                     "us_per_call": us_unfused,
                     "derived": f"lookups_per_s="
                                f"{b * n * m / (us_unfused / 1e6):.2e} "
                                "(executor window scan; masked)"})
        us_fused = _time(
            lambda c, q, k, r: pq_adc_fused_topk(c, q, k, r, topk,
                                                 use_kernel=False),
            codes, queries, cb, cand_rows)
        # acceptance gate: bit-identical top-k (dist, id) pairs at fp32
        v_u, i_u = pq_adc_topk_batch(codes, luts, topk, mask=mask,
                                     use_kernel=False)
        v_f, i_f = pq_adc_fused_topk(codes, queries, cb, cand_rows, topk,
                                     use_kernel=False)
        fin = np.isfinite(np.asarray(v_u))
        assert np.array_equal(np.asarray(v_f)[fin], np.asarray(v_u)[fin]) \
            and np.array_equal(np.asarray(i_f)[fin], np.asarray(i_u)[fin]), \
            f"fused/unfused top-k diverged at n={n}"
        rows.append({"name": f"kern.pq_adc_fused.b{b}.n{n}",
                     "us_per_call": us_fused,
                     "derived": f"speedup_vs_unfused="
                                f"{us_unfused / us_fused:.2f}x "
                                "(bit-identical top-k)"})
        us_int8 = _time(
            lambda c, q, k, r: pq_adc_fused_topk(c, q, k, r, topk,
                                                 use_kernel=False,
                                                 lut_int8=True),
            codes, queries, cb, cand_rows)
        rows.append({"name": f"kern.pq_adc_fused_int8.b{b}.n{n}",
                     "us_per_call": us_int8,
                     "derived": "fig10 int8-LUT accuracy level"})
    q = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)
    us = _time(lambda a, b: l2_distances(a, b, use_kernel=False), q, v)
    rows.append({"name": "kern.l2dist.64x4096x128", "us_per_call": us,
                 "derived": f"gflops={2*64*4096*128/us/1e3:.1f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
