"""Kernel micro-benchmarks: us/call of the ADC scan + exact-L2 oracle paths
(jnp on CPU; Pallas interpret path checked for parity, not speed)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.l2dist import l2_distances
from repro.kernels.pq_adc import pq_adc, pq_adc_topk, pq_adc_topk_batch


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []
    for n, m in [(65536, 32), (262144, 32)]:
        codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
        lut = jnp.asarray(rng.random((m, 256)), jnp.float32)
        us = _time(lambda c, l: pq_adc(c, l, use_kernel=False), codes, lut)
        lookups_per_s = n * m / (us / 1e6)
        rows.append({"name": f"kern.pq_adc.n{n}", "us_per_call": us,
                     "derived": f"lookups_per_s={lookups_per_s:.2e}"})
        us = _time(lambda c, l: pq_adc_topk(c, l, 256, use_kernel=False),
                   codes, lut)
        rows.append({"name": f"kern.pq_adc_topk.n{n}", "us_per_call": us,
                     "derived": "fused scan+topk (jnp path)"})
    # the executor's windowed scan: B queries amortise one pass over the
    # codes; the mask is the per-query candidate membership (stage ⑤)
    n, m, b = 65536, 32, 8
    codes = jnp.asarray(rng.integers(0, 256, (n, m)), jnp.uint8)
    luts = jnp.asarray(rng.random((b, m, 256)), jnp.float32)
    mask = jnp.asarray(rng.random((b, n)) < 0.1)
    us = _time(lambda c, l, mk: pq_adc_topk_batch(c, l, 256, mask=mk,
                                                  use_kernel=False),
               codes, luts, mask)
    rows.append({"name": f"kern.pq_adc_topk_batch.b{b}.n{n}",
                 "us_per_call": us,
                 "derived": f"lookups_per_s={b * n * m / (us / 1e6):.2e} "
                            "(executor window scan; masked)"})
    q = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)
    us = _time(lambda a, b: l2_distances(a, b, use_kernel=False), q, v)
    rows.append({"name": "kern.l2dist.64x4096x128", "us_per_call": us,
                 "derived": f"gflops={2*64*4096*128/us/1e3:.1f}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
