"""Shared benchmark substrate: datasets, indices, and system wrappers are
built once and cached across figures.  Scale via REPRO_BENCH_N (default
20,000 vectors; the paper runs 10^9 — all counts are per-query so the
*mechanisms* reproduce at reduced scale, see EXPERIMENTS.md §Repro)."""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Dict, List

import numpy as np

from repro.configs.anns_datasets import SIFT_SMALL
from repro.configs.base import ANNSConfig
from repro.core.baselines import (DiskAnnLike, HIGpu, HIPq, RummyLike,
                                  SpannLike)
from repro.core.engine import FusionANNSIndex, ground_truth, recall_at_k
from repro.core.perf_model import DeviceModel, demand_from_stats
from repro.serve.client import SearchRequest
from repro.data.synthetic import clustered_vectors

BENCH_N = int(os.environ.get("REPRO_BENCH_N", 20000))
N_QUERIES = int(os.environ.get("REPRO_BENCH_QUERIES", 48))

# reduced-scale stand-ins for the paper's three datasets (Table 1):
# same dim ratios/dtypes, Gaussian-mixture distribution
DATASETS = {
    "sift": dict(dim=128, dtype=np.float32),   # SIFT1B: 128-d uint8
    "spacev": dict(dim=100, dtype=np.float32),  # SPACEV1B: 100-d int8
    "deep": dict(dim=96, dtype=np.float32),    # DEEP1B: 96-d float32
}

HW = DeviceModel()


@dataclasses.dataclass
class Bundle:
    cfg: ANNSConfig
    data: np.ndarray
    queries: np.ndarray
    gt: np.ndarray
    index: FusionANNSIndex


@functools.lru_cache(maxsize=4)
def bundle(dataset: str = "sift", n: int = BENCH_N) -> Bundle:
    spec = DATASETS[dataset]
    seed = {"sift": 11, "spacev": 22, "deep": 33}[dataset]
    rng = np.random.default_rng(seed)
    cfg = dataclasses.replace(
        SIFT_SMALL, name=dataset, n_vectors=n, dim=spec["dim"],
        pq_m=spec["dim"] // 4,    # dsub=4 — the 1B configs' compression rate
        n_posting_fraction=0.02, top_m=24, top_n=256, rerank_batch=32)
    # queries are held-out draws from the same mixture (standard protocol)
    everything = clustered_vectors(rng, n + N_QUERIES, spec["dim"],
                                   n_clusters=max(16, n // 400))
    data, queries = everything[:n], everything[n:]
    t0 = time.time()
    index = FusionANNSIndex.build(data, cfg)
    print(f"# [{dataset}] index build {time.time()-t0:.1f}s "
          f"({index.posting.n_clusters} lists, "
          f"replication {index.posting.replication_factor():.2f}x)")
    gt = ground_truth(data, queries, 10)
    return Bundle(cfg=cfg, data=data, queries=queries, gt=gt, index=index)


def fusion_demand(index: FusionANNSIndex, queries, *, fused: bool = False,
                  **kw) -> Dict:
    """Measured per-query demands + recall for the FusionANNS engine.

    ``fused=True`` routes the whole query set through one executor window
    (inter-query candidate dedup + one union scan), so the per-query
    h2d/scan demands reflect the batched operating point."""
    if fused:
        results = index.query_batch_fused(queries, **kw)
    else:
        results = [index.query(q, **kw) for q in queries]
    stats = [r.stats for r in results]
    totals = {f: float(np.sum([getattr(s, f) for s in stats]))
              for f in ("ios", "ssd_bytes", "h2d_bytes",
                        "candidates_scanned", "rerank_scored")}
    demand = demand_from_stats(totals, len(stats), pq_m=index.cfg.pq_m,
                               dim=index.ssd.vectors.shape[1],
                               top_m=index.cfg.top_m)
    return {"results": results, "demand": demand, "stats": stats}


def service_latency(index: FusionANNSIndex, queries, **svc_kw) -> Dict:
    """Drive the futures-path serving front-end over ``queries`` and
    report per-request p50/p99 enqueue->resolve latency (seconds).

    Backpressured submissions pump a batch through and retry, so the
    measured tail includes admission-control stalls — the operating point
    a deployment actually sees."""
    from repro.serve.anns_service import BackpressureError, \
        BatchingANNSService
    svc = BatchingANNSService(index, **svc_kw)
    futs = []
    for q in queries:
        while True:
            try:
                futs.append(svc.submit(SearchRequest(query=q)))
                break
            except BackpressureError:
                svc.pump(force=True)
    svc.drain()
    responses = [f.result() for f in futs]
    pct = svc.latency_percentiles()
    pct["responses"] = responses
    pct["stats"] = svc.stats
    return pct


def drive_producers(submit, queries, producers: int,
                    timeout: float = 300) -> List:
    """N producer threads submitting through ``submit`` (each retries
    through backpressure), then a blocking resolve of every future —
    real condition-variable waits against the serving threads.  Shared by
    the single-replica and routed traffic harnesses."""
    import threading
    from repro.serve.anns_service import BackpressureError
    futs: List[List] = [[] for _ in range(producers)]
    chunks = [queries[i::producers] for i in range(producers)]

    def produce(i):
        for q in chunks[i]:
            req = SearchRequest(query=q)
            while True:
                try:
                    futs[i].append(submit(req))
                    break
                except BackpressureError:
                    time.sleep(1e-3)

    threads = [threading.Thread(target=produce, args=(i,))
               for i in range(producers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return [f.result(timeout=timeout) for fs in futs for f in fs]


def service_latency_threaded(index: FusionANNSIndex, queries, *,
                             producers: int = 8, **svc_kw) -> Dict:
    """Drive the THREADED serving runtime (pump thread + ticker) from N
    producer threads against one replica and report per-request p50/p99
    enqueue->resolve latency (seconds).

    ``out_of_order_batches`` counts pump batches where the ticker retired
    a younger scan window before an older one finished re-ranking."""
    from repro.serve.anns_service import BatchingANNSService
    svc = BatchingANNSService(index, threaded=True, **svc_kw)
    responses = drive_producers(svc.submit, queries, producers)
    svc.stop()
    pct = svc.latency_percentiles()
    pct["responses"] = responses
    pct["stats"] = svc.stats

    def _ooo(events):
        fins = [wi for kind, wi in events if kind == "finish"]
        return any(fins[i] > fins[i + 1] for i in range(len(fins) - 1))

    pct["out_of_order_batches"] = sum(_ooo(ev) for ev in svc.ticket_events)
    return pct


def router_latency(index: FusionANNSIndex, queries, *, n_replicas: int = 2,
                   policy: str = "jsq", producers: int = 8,
                   **svc_kw) -> Dict:
    """Drive a :class:`~repro.serve.router.ReplicaRouter` (N threaded
    replicas behind one ``submit()``) from ``producers`` submitter threads
    and report aggregated p50/p99, the stats rollup, and the measured
    per-query demand the replica-scaling model consumes."""
    from repro.serve.router import ReplicaRouter
    router = ReplicaRouter(index, n_replicas=n_replicas, policy=policy,
                           threaded=True, **svc_kw)
    drive_producers(router.submit, queries, producers)
    router.stop()
    out = router.latency_percentiles()
    out["rollup"] = router.stats_rollup()
    out["demand"] = router.measured_demand()
    return out


def client_async_latency(index: FusionANNSIndex, queries, *,
                         n_replicas: int = 2, policy: str = "jsq",
                         max_inflight: int = 64, repeat: int = 1,
                         **svc_kw) -> Dict:
    """Drive the asyncio front door (``AsyncANNSClient`` — DESIGN.md §6)
    over an N-replica router: ONE event loop holds the whole workload in
    flight (vs a thread per producer), backpressure is awaited admission,
    and responses stream back in completion order.  Reports wall clock,
    per-request p50/p99 (submit->resolve, as measured by each
    ``SearchResponse.latency_s``), and the client's admission counters."""
    import asyncio
    from repro.serve.client import AsyncANNSClient, SearchRequest
    from repro.serve.router import ReplicaRouter
    router = ReplicaRouter(index, n_replicas=n_replicas, policy=policy,
                           threaded=True, **svc_kw)
    reqs = [SearchRequest(query=q, tag=i)
            for i, q in enumerate(np.concatenate([queries] * repeat))]

    async def drive():
        async with AsyncANNSClient(router,
                                   max_inflight=max_inflight) as client:
            t0 = time.perf_counter()
            resps = [r async for r in client.search_many(reqs)]
            return time.perf_counter() - t0, resps, dict(client.stats)

    try:
        wall, resps, cstats = asyncio.run(drive())
    finally:
        router.stop()
    lat = np.asarray([r.latency_s for r in resps])
    return {"p50": float(np.percentile(lat, 50)),
            "p99": float(np.percentile(lat, 99)), "n": len(lat),
            "wall_s": wall, "client_stats": cstats,
            "rollup": router.stats_rollup(), "responses": resps}


def edge_http_latency(index: FusionANNSIndex, queries, *,
                      n_replicas: int = 2, policy: str = "jsq",
                      connections: int = 16, repeat: int = 1,
                      **svc_kw) -> Dict:
    """Drive the HTTP edge (serve/edge.py) through a REAL loopback socket:
    an :class:`~repro.serve.edge.AnnsEdge` on an ephemeral port, fronted
    by ``connections`` keep-alive HTTP/1.1 connections each working
    through its share of the workload.  The measured p50/p99 are
    whole-request HTTP latencies (serialize -> socket -> parse -> auth ->
    coalesce -> client -> router -> replica -> response bytes), i.e. the
    full PR-7 front-door overhead on top of the in-process client path —
    the fig9 ``edge_http`` row."""
    import asyncio
    from repro.serve.edge import AnnsEdge, EdgeConfig, HttpConn
    from repro.serve.stack import make_serving_stack
    router = make_serving_stack(index, n_replicas=n_replicas,
                                policy=policy, **svc_kw)
    work = np.concatenate([queries] * repeat)

    async def drive():
        async with AnnsEdge(router, EdgeConfig(),
                            own_backend=True) as edge:
            conns = [await HttpConn.open(edge.cfg.host, edge.port)
                     for _ in range(connections)]
            lat: List[float] = []

            async def pump(ci: int) -> None:
                for q in work[ci::connections]:
                    t0 = time.perf_counter()
                    status, doc = await conns[ci].request(
                        "POST", "/v1/search", {"query": q.tolist()})
                    lat.append(time.perf_counter() - t0)
                    assert status == 200, doc

            t0 = time.perf_counter()
            await asyncio.gather(*[pump(i) for i in range(connections)])
            wall = time.perf_counter() - t0
            _, stats = await conns[0].request("GET", "/v1/stats")
            for c in conns:
                await c.aclose()
            return wall, lat, stats

    wall, lat, stats = asyncio.run(drive())
    arr = np.asarray(lat)
    return {"p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)), "n": len(arr),
            "wall_s": wall, "edge_stats": stats}


def tune_for_recall(index, queries, gt, target: float,
                    top_ms=(8, 16, 24, 48, 96), top_ns=(128, 256, 512)):
    """Find the cheapest (top_m, top_n) reaching the recall target —
    the paper's per-accuracy-level operating points."""
    for top_m in top_ms:
        for top_n in top_ns:
            res = [index.query(q, top_m=top_m, top_n=top_n)
                   for q in queries]
            rec = recall_at_k(np.stack([r.ids for r in res]), gt, 10)
            if rec >= target:
                return top_m, top_n, rec
    return top_ms[-1], top_ns[-1], rec
