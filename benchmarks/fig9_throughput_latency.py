"""Fig. 9: QPS + latency of SPANN / DiskANN / RUMMY / FusionANNS across the
three dataset profiles at Recall@10>=0.9 (peak-thread operating point),
plus the futures-path rows: the pipelined inflight-depth sweep, the
serving front-end's p50/p99 through submit()/QueryFuture (PR 2), the
threaded runtime under 8 producer threads vs the synchronous pump
(PR 3), the multi-replica JSQ router with the 1/2/4-replica scaling
model (PR 4), the asyncio client front door over that router (PR 5),
the HTTP edge measured through a real loopback socket (PR 7), and the
deadline-adaptive accuracy resolver descending the level ladder as the
deadline tightens (PR 10)."""

import time

import numpy as np

from benchmarks.common import (HW, bundle, client_async_latency,
                               edge_http_latency, fusion_demand,
                               router_latency, service_latency,
                               service_latency_threaded)
from repro.core.baselines import DiskAnnLike, RummyLike, SpannLike
from repro.core.engine import recall_at_k
from repro.core.perf_model import (QueryDemand, qps_at_threads,
                                   latency_at_threads, sweep_replicas)


def _mean_demand(results) -> QueryDemand:
    fields = ("ssd_ios", "ssd_bytes", "h2d_bytes", "gpu_lookups",
              "cpu_lookups", "cpu_dist_ops", "graph_hops")
    return QueryDemand(**{f: float(np.mean([getattr(r.demand, f)
                                            for r in results]))
                          for f in fields})


def best_qps(demand, threads=(1, 2, 4, 8, 16, 32, 64)):
    best = max(threads, key=lambda t: qps_at_threads(demand, HW, t))
    return (qps_at_threads(demand, HW, best),
            latency_at_threads(demand, HW, best), best)


def _pipeline_depth_row(b) -> dict:
    """Queue depth 1 vs 2+ through the executor's _InflightQueue: same ids
    (tested elsewhere), different host/device interleave.  Reports wall
    clock per depth plus the dispatch-ahead count from the event probe."""
    nq = min(32, len(b.queries))
    # warm the scan's jit cache so depth 1 doesn't absorb compile time
    b.index.executor.submit(b.queries[:nq],
                            b.index.plan(window=8)).wait()
    walls = {}
    ahead = 0
    n_w = 0
    for depth in (1, 2, 3):
        plan = b.index.plan(window=8, inflight_depth=depth)
        t0 = time.perf_counter()
        ticket = b.index.executor.submit(b.queries[:nq], plan)
        ticket.wait()
        walls[depth] = time.perf_counter() - t0
        if depth == 2:
            disp = {wi: i for i, (k, wi) in enumerate(ticket.events)
                    if k == "dispatch"}
            fin = {wi: i for i, (k, wi) in enumerate(ticket.events)
                   if k == "finish"}
            n_w = len(disp)
            ahead = sum(int(disp[t + 1] < fin[t]) for t in range(n_w - 1))
    return {
        "name": "fig9.sift.pipeline_depth",
        "us_per_call": walls[2] / nq * 1e6,
        "derived": (f"wall_ms d1={walls[1]*1e3:.1f} d2={walls[2]*1e3:.1f} "
                    f"d3={walls[3]*1e3:.1f}; "
                    f"d2 dispatched-ahead {ahead}/{max(n_w-1, 1)} windows "
                    f"(scan t+1 in flight during rerank t)"),
    }


def _service_latency_row(b) -> dict:
    """Serving front-end p50/p99 through the futures path (submit ->
    QueryFuture.result), batch 16, pipelined scan windows."""
    lat = service_latency(b.index, b.queries, max_batch=16, max_wait_s=0.0,
                          scan_window=8, inflight_depth=2)
    return {
        "name": "fig9.sift.service_futures",
        "us_per_call": lat["p50"] * 1e6,
        "derived": (f"p50={lat['p50']*1e3:.2f}ms p99={lat['p99']*1e3:.2f}ms "
                    f"n={lat['n']} mean_batch="
                    f"{lat['stats']['mean_batch']:.1f}"),
    }


def _service_threaded_row(b) -> tuple:
    """Threaded serving runtime (PR 3): 8 producer threads submitting
    against ONE replica (pump thread + out-of-order ticker), p50/p99 vs
    the synchronous pump driving the same traffic.  Returns (row, thr) so
    the router row can reuse the single-replica measurement instead of
    re-running the whole threaded pass."""
    sync = service_latency(b.index, b.queries, max_batch=16, max_wait_s=0.0,
                           scan_window=8, inflight_depth=2)
    thr = service_latency_threaded(
        b.index, b.queries, producers=8, max_batch=16, max_wait_s=0.0005,
        scan_window=8, inflight_depth=2)
    row = {
        "name": "fig9.sift.service_threaded",
        "us_per_call": thr["p50"] * 1e6,
        "derived": (f"8 producers: p50={thr['p50']*1e3:.2f}ms "
                    f"p99={thr['p99']*1e3:.2f}ms n={thr['n']} "
                    f"ooo_batches={thr['out_of_order_batches']}"
                    f"/{int(thr['stats']['batches'])} | sync pump: "
                    f"p50={sync['p50']*1e3:.2f}ms "
                    f"p99={sync['p99']*1e3:.2f}ms"),
    }
    return row, thr


def _router_jsq_row(b, single) -> dict:
    """Multi-replica routing (serve/router.py): 8 producers against TWO
    threaded replicas behind one JSQ router, p50/p99 + routed split, plus
    the replica-scaling model (one mesh carved into 1/2/4 device groups)
    on the demand measured through the router.  ``single`` is the
    single-replica threaded measurement from ``_service_threaded_row``."""
    lat = router_latency(b.index, b.queries, n_replicas=2, policy="jsq",
                         producers=8, max_batch=16, max_wait_s=0.0005,
                         scan_window=8, inflight_depth=2)
    sweep = sweep_replicas(lat["demand"], HW, (1, 2, 4))
    return {
        "name": "fig9.sift.router_jsq",
        "us_per_call": lat["p50"] * 1e6,
        "derived": (f"2 replicas x 8 producers: p50={lat['p50']*1e3:.2f}ms "
                    f"p99={lat['p99']*1e3:.2f}ms "
                    f"routed={lat['rollup']['routed']} "
                    f"spills={lat['rollup']['spills']} | 1 replica: "
                    f"p50={single['p50']*1e3:.2f}ms | modelled qps "
                    f"r1={sweep[1]:.0f} r2={sweep[2]:.0f} r4={sweep[4]:.0f}"),
    }


def _client_async_row(b) -> dict:
    """The asyncio front door (PR 5): one event loop holding the whole
    workload in flight over a 2-replica JSQ router — p50/p99 per-request
    latency plus awaited-admission counters (the client never surfaces
    BackpressureError)."""
    lat = client_async_latency(
        b.index, b.queries, n_replicas=2, policy="jsq", max_inflight=64,
        repeat=2, max_batch=16, max_wait_s=0.0005, scan_window=8,
        inflight_depth=2)
    return {
        "name": "fig9.sift.client_async",
        "us_per_call": lat["p50"] * 1e6,
        "derived": (f"1 loop x {lat['n']} reqs over 2 replicas: "
                    f"p50={lat['p50']*1e3:.2f}ms p99={lat['p99']*1e3:.2f}ms "
                    f"wall={lat['wall_s']*1e3:.0f}ms "
                    f"admission_waits="
                    f"{lat['client_stats']['admission_waits']} "
                    f"routed={lat['rollup']['routed']}"),
    }


def _edge_http_row(b) -> dict:
    """The HTTP front door (PR 7): whole-request latency through a REAL
    loopback socket — 16 keep-alive connections against an AnnsEdge over
    a 2-replica JSQ router, with request coalescing on.  The p50 delta
    vs fig9.sift.client_async is the HTTP+socket overhead itself."""
    lat = edge_http_latency(
        b.index, b.queries, n_replicas=2, policy="jsq", connections=16,
        repeat=2, max_batch=16, max_wait_s=0.0005, scan_window=8,
        inflight_depth=2)
    es = lat["edge_stats"]
    return {
        "name": "fig9.sift.edge_http",
        "us_per_call": lat["p50"] * 1e6,
        "derived": (f"16 conns x {lat['n']} reqs over HTTP: "
                    f"p50={lat['p50']*1e3:.2f}ms p99={lat['p99']*1e3:.2f}ms "
                    f"wall={lat['wall_s']*1e3:.0f}ms "
                    f"ok={es['edge']['ok']} "
                    f"coalesced={es['client']['coalesced']} "
                    f"backend_submits={es['client']['submitted']}"),
    }


def _deadline_adaptive_row(b) -> dict:
    """Deadline-adaptive accuracy (PR 10 — DESIGN.md §11): feed the
    planner the REAL served stats, then tighten the deadline and let it
    descend the accuracy ladder; every adapted operating point is re-run
    for real to report recall + measured candidate reduction, and its
    re-measured modeled latency must fit the deadline that picked it.
    A serve-path pass (``adaptive=True`` requests through the batching
    service with a wall-clock deadline) proves the wiring end to end —
    zero deadline misses.  The "fit" count uses the resolver's own
    contract: the PREDICTED latency of the chosen level fits the
    deadline, with the cheapest level as the explicit best-effort floor
    when nothing does."""
    from repro.core.futures import DeadlineExceeded
    from repro.core.perf_model import (ACCURACY_LEVELS, AdaptivePlanner,
                                       demand_from_stats, scale_demand,
                                       single_thread_latency)
    from repro.serve.anns_service import BatchingANNSService
    from repro.serve.client import SearchRequest

    def modeled(results):
        stats = [r.stats for r in results]
        totals = {f: float(np.sum([getattr(s, f) for s in stats]))
                  for f in ("ios", "ssd_bytes", "h2d_bytes",
                            "candidates_scanned", "rerank_scored")}
        d = demand_from_stats(totals, len(stats), pq_m=b.cfg.pq_m,
                              dim=b.data.shape[1], top_m=b.cfg.top_m)
        return single_thread_latency(d, HW), d, stats

    ex = b.index.executor
    full = ex.run(b.queries, b.index.plan())
    base_lat, d_full, full_stats = modeled(full)
    planner = AdaptivePlanner(b.cfg, HW, dim=b.data.shape[1])
    for s in full_stats:
        planner.observe(s)

    parts, fit, tried, wall = [], 0, 0, 0.0
    for frac in (0.6, 0.25):
        deadline = base_lat * frac
        sug = planner.suggest(deadline)
        lvl = next(l for l in ACCURACY_LEVELS
                   if l.name == (sug["level"] if sug else "full"))
        pred = single_thread_latency(scale_demand(d_full, lvl), HW)
        plan = b.index.plan() if sug is None else \
            b.index.plan(top_m=sug["top_m"], top_n=sug["top_n"])
        t0 = time.perf_counter()
        res = ex.run(b.queries, plan)
        wall = time.perf_counter() - t0
        lat, _, stats = modeled(res)
        rec = recall_at_k(np.stack([r.ids for r in res]), b.gt, 10)
        tried += 1
        fit += int(pred <= deadline * planner.headroom
                   or lvl is ACCURACY_LEVELS[-1])
        parts.append(f"dl={deadline*1e3:.2f}ms level={lvl.name} "
                     f"pred={pred*1e3:.2f}ms meas={lat*1e3:.2f}ms "
                     f"recall={rec:.3f} "
                     f"scanned={np.mean([s.candidates_scanned for s in stats]):.0f}")

    # serve-path wiring: adaptive requests with a wall-clock deadline
    svc = BatchingANNSService(b.index, threaded=True, max_batch=16,
                              max_wait_s=0.0005)
    try:
        futs = [svc.submit(SearchRequest(query=q, k=10, deadline_s=1.0,
                                         adaptive=True))
                for q in b.queries]
        misses = 0
        for f in futs:
            try:
                f.result()
            except DeadlineExceeded:
                misses += 1
    finally:
        svc.stop()
    return {
        "name": "fig9.sift.deadline_adaptive",
        "us_per_call": wall / max(len(b.queries), 1) * 1e6,
        "derived": (f"full modeled={base_lat*1e3:.2f}ms "
                    f"recall={recall_at_k(np.stack([r.ids for r in full]), b.gt, 10):.3f} | "
                    + " | ".join(parts)
                    + f" | resolver fit {fit}/{tried} (floor=best-effort)"
                    + f" | serve adaptive: {len(b.queries)-misses}"
                    f"/{len(b.queries)} in wall deadline"),
    }


def run():
    rows = []
    for ds in ("sift", "spacev", "deep"):
        b = bundle(ds)
        diskann = DiskAnnLike(b.data, degree=24)
        systems = {}
        fus = fusion_demand(b.index, b.queries)
        systems["FusionANNS"] = (fus["demand"],
                                 np.stack([r.ids for r in fus["results"]]))
        # executor window mode: union scan + inter-query dedup (§4.3 on HBM)
        fusb = fusion_demand(b.index, b.queries, fused=True)
        systems["FusionANNS-batched"] = (
            fusb["demand"], np.stack([r.ids for r in fusb["results"]]))
        sp = [SpannLike(b.index, b.data).query(q, 10, b.cfg.top_m)
              for q in b.queries]
        systems["SPANN"] = (_mean_demand(sp), np.stack([r.ids for r in sp]))
        ru = [RummyLike(b.index, b.data).query(q, 10, b.cfg.top_m)
              for q in b.queries]
        systems["RUMMY"] = (_mean_demand(ru), np.stack([r.ids for r in ru]))
        da = [diskann.query(q, 10) for q in b.queries]
        systems["DiskANN"] = (_mean_demand(da), np.stack([r.ids for r in da]))

        qps_map = {}
        for name, (demand, ids) in systems.items():
            rec = recall_at_k(ids, b.gt, 10)
            qps, lat, t = best_qps(demand)
            qps_map[name] = qps
            rows.append({
                "name": f"fig9.{ds}.{name}",
                "us_per_call": lat * 1e6,
                "derived": f"qps={qps:.0f}@t{t} recall={rec:.3f}",
            })
        rows.append({
            "name": f"fig9.{ds}.speedup",
            "us_per_call": 0,
            "derived": (f"vs_spann={qps_map['FusionANNS']/qps_map['SPANN']:.1f}x "
                        f"vs_diskann={qps_map['FusionANNS']/qps_map['DiskANN']:.1f}x "
                        f"vs_rummy={qps_map['FusionANNS']/qps_map['RUMMY']:.1f}x "
                        f"(paper: 9.4-13.1x / 3.2-4.3x / 2-4.9x)"),
        })
        if ds == "sift":
            rows.append(_pipeline_depth_row(b))
            rows.append(_service_latency_row(b))
            srow, thr = _service_threaded_row(b)
            rows.append(srow)
            rows.append(_router_jsq_row(b, thr))
            rows.append(_client_async_row(b))
            rows.append(_edge_http_row(b))
            rows.append(_deadline_adaptive_row(b))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
