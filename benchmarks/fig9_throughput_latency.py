"""Fig. 9: QPS + latency of SPANN / DiskANN / RUMMY / FusionANNS across the
three dataset profiles at Recall@10>=0.9 (peak-thread operating point)."""

import numpy as np

from benchmarks.common import HW, bundle, fusion_demand
from repro.core.baselines import DiskAnnLike, RummyLike, SpannLike
from repro.core.engine import recall_at_k
from repro.core.perf_model import (QueryDemand, qps_at_threads,
                                   latency_at_threads)


def _mean_demand(results) -> QueryDemand:
    fields = ("ssd_ios", "ssd_bytes", "h2d_bytes", "gpu_lookups",
              "cpu_lookups", "cpu_dist_ops", "graph_hops")
    return QueryDemand(**{f: float(np.mean([getattr(r.demand, f)
                                            for r in results]))
                          for f in fields})


def best_qps(demand, threads=(1, 2, 4, 8, 16, 32, 64)):
    best = max(threads, key=lambda t: qps_at_threads(demand, HW, t))
    return (qps_at_threads(demand, HW, best),
            latency_at_threads(demand, HW, best), best)


def run():
    rows = []
    for ds in ("sift", "spacev", "deep"):
        b = bundle(ds)
        diskann = DiskAnnLike(b.data, degree=24)
        systems = {}
        fus = fusion_demand(b.index, b.queries)
        systems["FusionANNS"] = (fus["demand"],
                                 np.stack([r.ids for r in fus["results"]]))
        # executor window mode: union scan + inter-query dedup (§4.3 on HBM)
        fusb = fusion_demand(b.index, b.queries, fused=True)
        systems["FusionANNS-batched"] = (
            fusb["demand"], np.stack([r.ids for r in fusb["results"]]))
        sp = [SpannLike(b.index, b.data).query(q, 10, b.cfg.top_m)
              for q in b.queries]
        systems["SPANN"] = (_mean_demand(sp), np.stack([r.ids for r in sp]))
        ru = [RummyLike(b.index, b.data).query(q, 10, b.cfg.top_m)
              for q in b.queries]
        systems["RUMMY"] = (_mean_demand(ru), np.stack([r.ids for r in ru]))
        da = [diskann.query(q, 10) for q in b.queries]
        systems["DiskANN"] = (_mean_demand(da), np.stack([r.ids for r in da]))

        qps_map = {}
        for name, (demand, ids) in systems.items():
            rec = recall_at_k(ids, b.gt, 10)
            qps, lat, t = best_qps(demand)
            qps_map[name] = qps
            rows.append({
                "name": f"fig9.{ds}.{name}",
                "us_per_call": lat * 1e6,
                "derived": f"qps={qps:.0f}@t{t} recall={rec:.3f}",
            })
        rows.append({
            "name": f"fig9.{ds}.speedup",
            "us_per_call": 0,
            "derived": (f"vs_spann={qps_map['FusionANNS']/qps_map['SPANN']:.1f}x "
                        f"vs_diskann={qps_map['FusionANNS']/qps_map['DiskANN']:.1f}x "
                        f"vs_rummy={qps_map['FusionANNS']/qps_map['RUMMY']:.1f}x "
                        f"(paper: 9.4-13.1x / 3.2-4.3x / 2-4.9x)"),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
