"""Fig. 5: (a) recall vs fixed re-rank number; (b) variance of the minimum
re-rank number across queries — the motivation for heuristic re-ranking."""

import numpy as np

from benchmarks.common import bundle
from repro.core.engine import recall_at_k


def run():
    b = bundle("sift")
    rows = []
    # (a): recall@10 with fixed re-rank depth (early stop disabled,
    # top_n = depth)
    for depth in (10, 20, 40, 80, 160, 256):
        res = [b.index.query(q, top_n=depth, disable_early_stop=True)
               for q in b.queries]
        rec = recall_at_k(np.stack([r.ids for r in res]), b.gt, 10)
        frac_perfect = float(np.mean([
            len(set(r.ids.tolist()) & set(g.tolist())) == 10
            for r, g in zip(res, b.gt)]))
        rows.append({"name": f"fig5a.rerank{depth}",
                     "us_per_call": 0,
                     "derived": f"recall={rec:.3f} "
                                f"frac_queries_perfect={frac_perfect:.2f}"})
    # (b): minimum re-rank number per query = candidates scanned until the
    # exact top-10 is found
    mins = []
    for qi, q in enumerate(b.queries):
        res = b.index.query(q, top_n=256, disable_early_stop=True)
        # find earliest prefix of the PQ-ordered candidates covering gt
        ids = b.index.candidate_ids(q, b.cfg.top_m)
        import jax.numpy as jnp
        from repro.core import pq
        lut = pq.adc_lut(b.index.codebook, jnp.asarray(q))
        codes = jnp.take(b.index.codes, jnp.asarray(ids), axis=0)
        order = ids[np.argsort(np.asarray(pq.adc_distances_ref(lut, codes)))]
        gtset = set(b.gt[qi].tolist())
        found, need = 0, min(len(gtset & set(order.tolist())), 10)
        pos = 0
        for i, vid in enumerate(order):
            if int(vid) in gtset:
                found += 1
                pos = i + 1
                if found >= need:
                    break
        mins.append(pos)
    mins = np.array(mins)
    rows.append({"name": "fig5b.min_rerank_depth",
                 "us_per_call": 0,
                 "derived": (f"p10={np.percentile(mins,10):.0f} "
                             f"p50={np.percentile(mins,50):.0f} "
                             f"p90={np.percentile(mins,90):.0f} "
                             f"max={mins.max()} (variance motivates Alg.1)")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
